#!/usr/bin/env python
"""Docs snippet runner: every fenced ```python block in README.md and
docs/*.md must import and run cleanly, so documentation cannot rot
silently. Wired into CI (.github/workflows/ci.yml, docs job).

Each snippet runs in its own subprocess from the repo root with
``PYTHONPATH=src``. A block can opt out by placing the marker

    <!-- snippet: no-run -->

on any of the three lines above its opening fence (use sparingly — e.g.
for illustrative pseudo-code).

Usage: python tools/check_doc_snippets.py [files...]
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
NO_RUN = "<!-- snippet: no-run -->"
TIMEOUT_S = 600


def extract_snippets(path: Path):
    """Yield (start_line, source) for each runnable ```python block."""
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip().startswith("```python"):
            skip = any(NO_RUN in lines[j]
                       for j in range(max(0, i - 3), i))
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].strip().startswith("```"):
                body.append(lines[i])
                i += 1
            if not skip:
                yield start + 1, "\n".join(body)
        i += 1


def run_snippet(src: str, label: str) -> bool:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(src)
        tmp = f.name
    try:
        proc = subprocess.run([sys.executable, tmp], cwd=ROOT, env=env,
                              capture_output=True, text=True,
                              timeout=TIMEOUT_S)
    finally:
        os.unlink(tmp)
    if proc.returncode != 0:
        print(f"FAIL {label}\n--- stdout ---\n{proc.stdout}"
              f"\n--- stderr ---\n{proc.stderr}")
        return False
    print(f"ok   {label}")
    return True


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    files = ([Path(a) for a in args] if args
             else [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))])
    n = failures = 0
    for path in files:
        for line, src in extract_snippets(path):
            n += 1
            if not run_snippet(src, f"{path.relative_to(ROOT)}:{line}"):
                failures += 1
    print(f"\n{n - failures}/{n} snippets passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
