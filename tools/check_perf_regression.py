#!/usr/bin/env python
"""CI perf-regression guard: compare freshly-run quick benchmarks against
the checked-in full-sweep baselines and fail on throughput regression.

Each check pairs a quick-run report (written by
``benchmarks/bench_*.py --quick``) with its committed baseline
(``benchmarks/results/BENCH_*.json``), matches rows by a key tuple (the
quick sweep point is also a row of the full baseline sweep, so the
comparison is like-for-like), and fails when

    current_metric < baseline_metric * (1 - threshold)

The default threshold is 0.30 (a >30% throughput drop fails); override
with ``--threshold`` or the ``PERF_GUARD_THRESHOLD`` env var (CI runners
with very different hardware from the baseline machine may need a looser
setting). Rows present in only one report are reported but never fail
the guard (a new sweep point has no baseline yet).

Checks come in four kinds: plain baseline comparisons (higher is
better), ``direction="lower"`` baseline comparisons for latency rows
(fail when the current value EXCEEDS baseline * (1 + threshold)),
``kind="within"`` same-report ratios (machine-independent), and
``kind="floor"`` absolute metric floors (hard product claims the
threshold does not soften).

Usage: python tools/check_perf_regression.py [--threshold 0.30]
Wired into CI (.github/workflows/ci.yml, perf-guard job) after the quick
benchmark runs.
"""

from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from aqplint.perfrows import compare, meets_floor, rows_by_key  # noqa: E402

RESULTS = Path(__file__).resolve().parent.parent / "benchmarks" / "results"

CHECKS = [
    # absolute throughput (what the guard is for; sensitive to runner
    # hardware — loosen PERF_GUARD_THRESHOLD if runners drift from the
    # baseline machine) ...
    dict(name="fused_scan",
         current="BENCH_fused_scan_quick.json",
         baseline="BENCH_fused_scan.json",
         key=("nb", "hist"),
         metric="fused_blocks_per_s"),
    dict(name="serve",
         current="BENCH_serve_quick.json",
         baseline="BENCH_serve.json",
         key=("workload", "nb"),
         metric="served_qps"),
    dict(name="device_loop",
         current="BENCH_device_loop_quick.json",
         baseline="BENCH_device_loop.json",
         key=("config",),
         metric="device_rounds_per_s"),
    dict(name="bound_eval",
         current="BENCH_bound_eval_quick.json",
         baseline="BENCH_bound_eval.json",
         key=("G",),
         metric="batched_refreshes_per_s"),
    dict(name="sharded_scan",
         current="BENCH_sharded_scan_quick.json",
         baseline="BENCH_sharded_scan.json",
         key=("config",),
         metric="rounds_per_s"),
    dict(name="scheduler",
         current="BENCH_scheduler_quick.json",
         baseline="BENCH_scheduler.json",
         key=("workload", "nb"),
         metric="scheduler_qps"),
    # latency rows are lower-is-better: fail when current EXCEEDS the
    # baseline by more than the threshold
    dict(name="scheduler-p50",
         current="BENCH_scheduler_quick.json",
         baseline="BENCH_scheduler.json",
         key=("workload", "nb"),
         metric="p50_latency_ms",
         direction="lower"),
    dict(name="scheduler-p99",
         current="BENCH_scheduler_quick.json",
         baseline="BENCH_scheduler.json",
         key=("workload", "nb"),
         metric="p99_latency_ms",
         direction="lower"),
    # ... plus machine-independent within-run ratios, robust to hardware
    dict(name="fused_scan-ratio",
         current="BENCH_fused_scan_quick.json",
         baseline="BENCH_fused_scan.json",
         key=("nb", "hist"),
         metric="speedup_vs_per_round"),
    dict(name="serve-ratio",
         current="BENCH_serve_quick.json",
         baseline="BENCH_serve.json",
         key=("workload", "nb"),
         metric="speedup"),
    dict(name="device_loop-ratio",
         current="BENCH_device_loop_quick.json",
         baseline="BENCH_device_loop.json",
         key=("config",),
         metric="speedup_vs_host_loop"),
    dict(name="bound_eval-ratio",
         current="BENCH_bound_eval_quick.json",
         baseline="BENCH_bound_eval.json",
         key=("G",),
         metric="speedup"),
    dict(name="sharded_scan-ratio",
         current="BENCH_sharded_scan_quick.json",
         baseline="BENCH_sharded_scan.json",
         key=("config",),
         metric="speedup_vs_single"),
    dict(name="scheduler-ratio",
         current="BENCH_scheduler_quick.json",
         baseline="BENCH_scheduler.json",
         key=("workload", "nb"),
         metric="speedup"),
    # hard product floor, machine-independent: continuous batching must
    # sustain >= 2x sequential q/s on the shared-signature burst trace
    dict(name="scheduler-burst-floor",
         kind="floor",
         current="BENCH_scheduler_quick.json",
         key=("workload", "nb"),
         row=("burst", 512),
         metric="speedup",
         floor=2.0),
    # hard scaling floor, the divided-scan product claim: each shard
    # gathers+folds only its own row slice, so on parallel hardware
    # (the *_par projection rows: serialized one-core time / n_shards)
    # 2 shards must beat one device outright
    dict(name="sharded_scan-parallel-floor",
         kind="floor",
         current="BENCH_sharded_scan_quick.json",
         key=("config",),
         row=("mesh2_k1_par",),
         metric="speedup_vs_single",
         floor=1.0),
    # per-shard scaling floor: efficiency = speedup_vs_single / n_shards
    dict(name="sharded_scan-efficiency",
         current="BENCH_sharded_scan_quick.json",
         baseline="BENCH_sharded_scan.json",
         key=("config",),
         metric="efficiency"),
    # ... plus within-ONE-run invariants (no baseline involved at all):
    # the amortized collective cadence must not be slower than the
    # per-round-merge path it amortizes. On a real multi-chip mesh
    # merge_every=4 is strictly faster; on the oversubscribed fake-CPU
    # mesh CI runs on, the relief is a few percent and can sit inside
    # timing noise, so the check fails only when k4 loses by more than
    # the guard threshold (a real cadence regression, not jitter).
    dict(name="sharded_scan-cadence",
         kind="within",
         current="BENCH_sharded_scan_quick.json",
         key=("config",),
         metric="rounds_per_s",
         faster="mesh2_k4",
         slower="mesh2_k1"),
    # checkpoint overhead bound: snapshotting every scheduler step
    # (burst_ckpt) must hold burst throughput to within 5% — the
    # snapshot is O(live state) numpy copies, never a device sync, and
    # this row keeps it that way. Tighter than the global threshold on
    # purpose: both rows come from the same run on the same machine.
    dict(name="scheduler-ckpt-overhead",
         kind="within",
         current="BENCH_scheduler_quick.json",
         key=("workload", "nb"),
         metric="scheduler_qps",
         faster=("burst_ckpt", 512),
         slower=("burst", 512),
         threshold=0.05),
]


def check_one(spec, threshold: float, results_dir: Path = RESULTS) -> int:
    cur_path = results_dir / spec["current"]
    base_path = results_dir / spec["baseline"]
    if not cur_path.exists():
        print(f"MISSING {spec['name']}: no quick report at "
              f"{cur_path.name} (run the quick benchmark first)")
        return 1
    if not base_path.exists():
        print(f"MISSING {spec['name']}: no committed baseline "
              f"{base_path.name}")
        return 1
    cur = rows_by_key(cur_path, spec["key"])
    base = rows_by_key(base_path, spec["key"])
    metric = spec["metric"]
    direction = spec.get("direction", "higher")
    failures = 0
    compared = 0
    for k, row in sorted(cur.items(), key=str):
        if k not in base:
            print(f"note {spec['name']}{k}: no baseline row, skipping")
            continue
        compared += 1
        got = float(row[metric])
        want = float(base[k][metric])
        ok, bound, label = compare(got, want, threshold, direction)
        verdict = "ok  " if ok else "FAIL"
        print(f"{verdict} {spec['name']}{k}: {metric} {got:.2f} vs "
              f"baseline {want:.2f} ({label} {bound:.2f})")
        if not ok:
            failures += 1
    for k in sorted(set(base) - set(cur), key=str):
        print(f"note {spec['name']}{k}: baseline-only row (not in quick "
              "sweep)")
    if compared == 0:
        # a sweep-point or key rename must not silently disable the guard
        print(f"FAIL {spec['name']}: zero rows matched between "
              f"{cur_path.name} and {base_path.name} — sweep points or "
              "key fields diverged; update the committed baseline")
        return failures + 1
    return failures


def check_within(spec, threshold: float,
                 results_dir: Path = RESULTS) -> int:
    """A ``kind="within"`` check compares two rows of the SAME current
    report (machine-independent by construction): the ``faster`` config
    must not trail the ``slower`` one by more than the threshold. A
    spec-level ``threshold`` overrides the global one (within-run rows
    share the machine and the run, so they can afford to be tighter)."""
    cur_path = results_dir / spec["current"]
    if not cur_path.exists():
        print(f"MISSING {spec['name']}: no quick report at "
              f"{cur_path.name} (run the quick benchmark first)")
        return 1
    cur = rows_by_key(cur_path, spec["key"])
    threshold = float(spec.get("threshold", threshold))
    rows = {}
    for role in ("faster", "slower"):
        v = spec[role]
        k = tuple(v) if isinstance(v, (list, tuple)) else (v,)
        if k not in cur:
            print(f"FAIL {spec['name']}: row {k} missing from "
                  f"{cur_path.name} — sweep points diverged from the "
                  "guard config")
            return 1
        rows[role] = float(cur[k][spec["metric"]])
    ok, floor, _ = compare(rows["faster"], rows["slower"], threshold)
    print(f"{'ok  ' if ok else 'FAIL'} {spec['name']}: "
          f"{spec['metric']}({spec['faster']}) {rows['faster']:.2f} vs "
          f"{spec['metric']}({spec['slower']}) {rows['slower']:.2f} "
          f"(floor {floor:.2f})")
    return 0 if ok else 1


def check_floor(spec, results_dir: Path = RESULTS) -> int:
    """A ``kind="floor"`` check holds one row of the current report to an
    absolute metric floor — a machine-independent product claim (e.g.
    continuous batching must beat sequential serving 2x), so the
    regression threshold does not soften it."""
    cur_path = results_dir / spec["current"]
    if not cur_path.exists():
        print(f"MISSING {spec['name']}: no quick report at "
              f"{cur_path.name} (run the quick benchmark first)")
        return 1
    cur = rows_by_key(cur_path, spec["key"])
    k = tuple(spec["row"])
    if k not in cur:
        print(f"FAIL {spec['name']}: row {k} missing from "
              f"{cur_path.name} — sweep points diverged from the guard "
              "config")
        return 1
    got = float(cur[k][spec["metric"]])
    floor = float(spec["floor"])
    ok = meets_floor(got, floor)
    print(f"{'ok  ' if ok else 'FAIL'} {spec['name']}{k}: "
          f"{spec['metric']} {got:.2f} (hard floor {floor:.2f})")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("PERF_GUARD_THRESHOLD",
                                                 0.30)),
                    help="allowed fractional throughput drop (default "
                         "0.30 = fail on >30%% regression)")
    args = ap.parse_args(argv)
    failures = 0
    for spec in CHECKS:
        if spec.get("kind") == "within":
            failures += check_within(spec, args.threshold)
        elif spec.get("kind") == "floor":
            failures += check_floor(spec)
        else:
            failures += check_one(spec, args.threshold)
    if failures:
        print(f"\n{failures} perf regression(s) beyond "
              f"{args.threshold:.0%}")
        return 1
    print("\nperf guard clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
