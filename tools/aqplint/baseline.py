"""Committed-baseline workflow.

The baseline maps ``"CODE::path::symbol"`` -> count. Line numbers are
deliberately not part of the key so edits above a baselined finding do
not un-baseline it. A run fails (exit 1) only on findings *beyond* the
baseline counts; findings that disappear are reported so the baseline
can be shrunk (``--write-baseline``), never grown silently.
"""

from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Tuple

from aqplint.core import Finding


def key_of(finding: Finding) -> str:
    code, path, symbol = finding.key()
    return f"{code}::{path}::{symbol}"


def load(path: Path) -> Dict[str, int]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def save(path: Path, findings: List[Finding]) -> None:
    counts = Counter(key_of(f) for f in findings)
    payload = {
        "comment": ("aqplint baseline: pre-existing findings tolerated "
                    "by CI. Shrink with --write-baseline after fixing; "
                    "never grow by hand."),
        "findings": dict(sorted(counts.items())),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def diff(findings: List[Finding],
         baseline: Dict[str, int]) -> Tuple[List[Finding], List[str]]:
    """Split into (new findings beyond baseline, stale baseline keys)."""
    counts = Counter(key_of(f) for f in findings)
    budget = dict(baseline)
    new: List[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        k = key_of(f)
        if budget.get(k, 0) > 0:
            budget[k] -= 1
        else:
            new.append(f)
    stale = sorted(k for k, v in baseline.items()
                   if counts.get(k, 0) < v)
    return new, stale
