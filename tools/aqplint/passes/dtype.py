"""AQP3xx — dtype discipline.

The (1-delta) guarantee math is only sound in f64: an f32 sqrt/log in a
bound evaluation loses ~7 decimal digits and the resulting interval can
exclude the true answer while every test that compares device-vs-host
*in the same dtype* still passes. JAX silently demotes to f32 unless
``jax_enable_x64`` is on, so the engine routes every device entry point
through ``state.require_x64()``.

AQP301 — f32 literal/cast (``jnp.float32``, ``np.float32``,
  ``dtype="float32"``) inside bound-eval code: ``*_device`` functions,
  methods of ``Bounder``/``StoppingCondition`` subclasses, and methods
  of the ``Stats``/``StatsBatch``/``DevStatsBatch`` snapshot structs.
  (Fold-side f32 — e.g. ``moments_of_batch`` accumulators in
  ``state.py`` — is outside this scope by design: folds are exact
  integer/moment sums whose f64 conversion happens at snapshot time.)

AQP302 — a module under ``src/`` (outside ``core/``) that *calls*
  bound-eval device twins must call ``require_x64`` somewhere: without
  the guard the twins run demoted and the guarantees are silently
  wrong.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from aqplint.core import Finding, Project

_STRUCT_CLASSES = {"Stats", "StatsBatch", "DevStatsBatch", "MomentState",
                   "HistState"}
_BASES = {"Bounder", "StoppingCondition"}

#: the modules that define the bound-eval API; every ``*_device`` name
#: they define is a twin whose caller needs the x64 guard (packing
#: helpers like fused_scan's pack_active_device are dtype-agnostic and
#: deliberately not in this set)
_CORE_STEMS = {"bounders", "count_sum", "optstop", "rangetrim", "state"}


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    _f32_in_bound_eval(project, findings)
    _guard_coverage(project, findings)
    return findings


# -- AQP301 ------------------------------------------------------------------


def _f32_in_bound_eval(project: Project, findings: List[Finding]) -> None:
    bound_classes = {c.name for c in project.subclasses_of(_BASES)}
    bound_classes |= _BASES | _STRUCT_CLASSES
    for mod in project.modules.values():
        for f in mod.functions.values():
            in_scope = (f.name.endswith("_device")
                        or f.parent_class in bound_classes)
            if not in_scope:
                continue
            for node in ast.walk(f.node):
                if getattr(node, "lineno", None) is None:
                    continue
                if mod.enclosing_function(node.lineno) != f.qualname:
                    continue
                hit = _f32_ref(mod, node)
                if hit:
                    findings.append(Finding(
                        code="AQP301", path=mod.relpath,
                        line=node.lineno, col=node.col_offset,
                        symbol=f.qualname,
                        message=(f"f32 literal/cast `{hit}` in bound-eval "
                                 "code — interval math must stay f64 or "
                                 "the (1-delta) guarantee is unsound")))
    return None


def _f32_ref(mod, node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and node.attr in (
            "float32", "float16", "bfloat16"):
        return f".{node.attr}"
    if isinstance(node, ast.Constant) and node.value in (
            "float32", "float16", "bfloat16"):
        return f'"{node.value}"'
    return None


# -- AQP302 ------------------------------------------------------------------


def _twin_names(project: Project) -> Set[str]:
    out: Set[str] = set()
    for mod in project.modules.values():
        if mod.name.rsplit(".", 1)[-1] not in _CORE_STEMS:
            continue
        for f in mod.functions.values():
            if f.name.endswith("_device"):
                out.add(f.name)
    return out


def _guard_coverage(project: Project, findings: List[Finding]) -> None:
    twins = _twin_names(project)
    if not twins:
        return
    for mod in project.modules.values():
        parts = mod.relpath.split("/")
        if "src" not in parts or "core" in parts:
            continue
        first_call = None
        has_guard = False
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            leaf = _leaf_name(node.func)
            if leaf == "require_x64":
                has_guard = True
            elif leaf in twins:
                if first_call is None:
                    first_call = (node, leaf)
        if first_call and not has_guard:
            node, leaf = first_call
            findings.append(Finding(
                code="AQP302", path=mod.relpath, line=node.lineno,
                col=node.col_offset,
                symbol=mod.enclosing_function(node.lineno),
                message=(f"module calls bound-eval device twin `{leaf}` "
                         "but never calls state.require_x64() — device "
                         "bound math would run silently demoted to f32")))


def _leaf_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None
