"""AQP104 — fault-injection hooks unreachable from production code.

``repro.testing`` (the deterministic fault-injection harness) exists so
chaos tests can drive the scheduler through failures. If production code
ever imported it, an injection point would sit on a real serving path —
the exact class of bug the harness exists to catch. The scheduler takes
its ``fault_hook`` as an opaque object precisely so serving code never
names the package; this pass machine-checks that contract: no module
under ``repro.`` (outside ``repro.testing`` itself) may import
``repro.testing``. Tests and benchmarks (module names not under
``repro.``) are exempt — that is who the harness is for.

AQP104 — production module imports repro.testing.
"""

from __future__ import annotations

import ast
from typing import List

from aqplint.core import Finding, Project

_PKG = "repro.testing"


def _is_production(name: str) -> bool:
    inside = name == "repro" or name.startswith("repro.")
    harness = name == _PKG or name.startswith(_PKG + ".")
    return inside and not harness


def _targets(node: ast.AST):
    """Dotted import targets of an Import/ImportFrom node."""
    if isinstance(node, ast.Import):
        for a in node.names:
            yield a.name
    elif isinstance(node, ast.ImportFrom) and node.module:
        yield node.module
        for a in node.names:
            yield f"{node.module}.{a.name}"


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules.values():
        if not _is_production(mod.name):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.Import, ast.ImportFrom)):
                continue
            hit = next(
                (t for t in _targets(node)
                 if t == _PKG or t.startswith(_PKG + ".")), None)
            if hit is None:
                continue
            findings.append(Finding(
                code="AQP104", path=mod.relpath, line=node.lineno,
                col=node.col_offset,
                symbol=mod.enclosing_function(node.lineno),
                message=(f"production module `{mod.name}` imports the "
                         f"fault-injection harness `{hit}`; injection "
                         "hooks must stay unreachable from serving "
                         "paths (pass them in as opaque objects from "
                         "test code)")))
    return findings
