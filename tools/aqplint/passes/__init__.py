"""Pass registry: every pass is ``run(project) -> list[Finding]``."""

from aqplint.passes import (collectives, dtype, faults, parity, purity,
                            shapes)

#: execution order (stable so output and baselines are deterministic)
ALL_PASSES = [
    ("purity", purity.run),
    ("parity", parity.run),
    ("dtype", dtype.run),
    ("collectives", collectives.run),
    ("shapes", shapes.run),
    ("faults", faults.run),
]
