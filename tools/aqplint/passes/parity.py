"""AQP2xx — triad parity.

The engine keeps bound-eval logic in up to three forms: scalar host
oracle, ``*_batch`` (numpy f64), and ``*_batch_device`` / ``*_device``
(jittable). The device twin is the one the production
``lax.while_loop`` actually runs — if it drifts from its host oracle
(missing override, extra/renamed parameter) the bitwise-equivalence
tests silently stop covering it. Three rules:

AQP201 — missing device twin: a class that overrides a ``*_batch``
  method (or ``active``) must override its ``*_device`` twin in the
  same class; a twin-covered module's public host function must have a
  module-level ``*_device`` sibling.
AQP202 — signature drift: the device twin's parameter list must be the
  host parameter list, optionally extended by the allowed device-only
  extras (``valid`` — device paths carry a validity mask because padded
  group slots exist on device).
AQP203 — orphan device twin: a ``*_device`` override with no host
  counterpart in the same class means the oracle no longer constrains
  the production path at all.

Class rules apply to (textual) subclasses of ``Bounder`` and
``StoppingCondition``. Full module coverage applies to modules named
``count_sum`` (every ``__all__`` function is twinned by policy);
everywhere else module-level pairs get drift checks only — e.g.
``state.moments_of_batch`` is fold-side f32 by design and has no twin.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from aqplint.core import ClassInfo, Finding, FunctionInfo, Project

#: device-side parameters a twin may append to the host signature
_ALLOWED_EXTRAS = ("valid",)

#: (host-method predicate, device suffix) class pairing rules
_BOUNDER_BASES = {"Bounder"}
_STOP_BASES = {"StoppingCondition"}


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    _class_rules(project, findings)
    _module_rules(project, findings)
    return findings


# -- class pairing -----------------------------------------------------------


def _class_rules(project: Project, findings: List[Finding]) -> None:
    for cls in project.subclasses_of(_BOUNDER_BASES | _STOP_BASES):
        stoppish = _inherits(project, cls, _STOP_BASES)
        for name, meth in sorted(cls.methods.items()):
            if name.endswith("_device"):
                host = name[: -len("_device")]
                if _is_twinned_name(host, stoppish) \
                        and host not in cls.methods:
                    findings.append(_f(
                        "AQP203", meth,
                        f"`{cls.name}.{name}` has no host counterpart "
                        f"`{host}` in the same class — the device path "
                        "is no longer pinned to the host oracle"))
                continue
            if not _is_twinned_name(name, stoppish):
                continue
            twin = cls.methods.get(name + "_device")
            if twin is None:
                findings.append(_f(
                    "AQP201", meth,
                    f"`{cls.name}.{name}` overridden without its device "
                    f"twin `{name}_device` — the jitted loop will run "
                    "the base-class bound for this class"))
                continue
            drift = _signature_drift(meth, twin)
            if drift:
                findings.append(_f(
                    "AQP202", twin,
                    f"`{cls.name}.{name}_device` signature drifted from "
                    f"`{name}`: {drift}"))


def _is_twinned_name(host_name: str, stoppish: bool) -> bool:
    if host_name.endswith("_batch"):
        return True
    return stoppish and host_name == "active"


def _inherits(project: Project, cls: ClassInfo, bases: set) -> bool:
    return cls in project.subclasses_of(bases)


# -- module-level pairing ----------------------------------------------------


def _module_rules(project: Project, findings: List[Finding]) -> None:
    for mod in project.modules.values():
        module_funcs = {q: f for q, f in mod.functions.items()
                        if "." not in q}
        full_coverage = mod.name.rsplit(".", 1)[-1] == "count_sum"
        if full_coverage:
            for name in _public_names(mod):
                if name.endswith("_device") or name not in module_funcs:
                    continue
                if name + "_device" not in module_funcs:
                    findings.append(_f(
                        "AQP201", module_funcs[name],
                        f"public function `{name}` in a fully-twinned "
                        f"module has no `{name}_device` twin"))
        for name, host in sorted(module_funcs.items()):
            if name.endswith("_device"):
                continue
            twin = module_funcs.get(name + "_device")
            if twin is None:
                continue
            drift = _signature_drift(host, twin)
            if drift:
                findings.append(_f(
                    "AQP202", twin,
                    f"`{name}_device` signature drifted from "
                    f"`{name}`: {drift}"))


def _public_names(mod) -> List[str]:
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                    out = []
                    if isinstance(node.value, (ast.List, ast.Tuple)):
                        for e in node.value.elts:
                            if isinstance(e, ast.Constant) and isinstance(
                                    e.value, str):
                                out.append(e.value)
                    return out
    return [q for q in mod.functions
            if "." not in q and not q.startswith("_")]


# -- shared ------------------------------------------------------------------


def _signature_drift(host: FunctionInfo,
                     twin: FunctionInfo) -> Optional[str]:
    h = _strip_self(host.params)
    d = _strip_self(twin.params)
    if d == h:
        return None
    # the twin may append allowed extras, in order, at the tail
    extras = d[len(h):]
    if (d[: len(h)] == h
            and all(e in _ALLOWED_EXTRAS for e in extras)):
        return None
    return (f"host has ({', '.join(h)}), device has ({', '.join(d)}); "
            f"device may only append {_ALLOWED_EXTRAS}")


def _strip_self(params: Tuple[str, ...]) -> Tuple[str, ...]:
    return params[1:] if params[:1] == ("self",) else params


def _f(code: str, fn: FunctionInfo, message: str) -> Finding:
    return Finding(code=code, path=fn.module.relpath, line=fn.lineno,
                   col=0, symbol=fn.qualname, message=message)
