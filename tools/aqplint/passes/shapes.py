"""AQP5xx — static-shape / retrace hygiene.

XLA compiles one executable per distinct input-shape signature. A
data-dependent output shape (``jnp.nonzero`` without ``size=``) either
errors under jit or — when the call sits just outside the jit boundary
— quietly forces a retrace per distinct selection count, which is
exactly the per-round retrace storm PR 3's static-shape padding fixed.
Slicing with a traced bound fails at trace time; a non-hashable static
arg raises on every call. All three are cheap to catch in the AST.

AQP501 — shape-producing call (``jnp.nonzero`` / ``flatnonzero`` /
  ``argwhere`` / ``unique``, or 1-arg ``jnp.where``) without ``size=``
  in jit-traced code.
AQP502 — slice bound that is a traced function parameter in jit-traced
  code (``x[:n]`` where ``n`` is a non-static param — use
  ``lax.dynamic_slice`` or a mask instead).
AQP503 — non-hashable literal (list/dict/set) passed to a declared
  ``static_argnames`` parameter of a jit-rooted project function.

The dynamic counterpart of this pass is :mod:`aqplint.retrace` — a
pytest helper that counts actual XLA compilations against committed
budgets (``tools/aqplint/retrace_budgets.json``).
"""

from __future__ import annotations

import ast
from typing import List, Optional

from aqplint.core import Finding, Project

_SIZE_REQUIRED = {"nonzero", "flatnonzero", "argwhere", "unique",
                  "unique_values"}


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules.values():
        for f in mod.functions.values():
            traced = f.fid in project.traced
            for node in ast.walk(f.node):
                if getattr(node, "lineno", None) is None:
                    continue
                if mod.enclosing_function(node.lineno) != f.qualname:
                    continue
                if isinstance(node, ast.Call):
                    if traced:
                        _check_size(mod, f, node, findings)
                    _check_static_args(project, mod, f, node, findings)
                elif traced and isinstance(node, ast.Subscript):
                    _check_slice(mod, f, node, findings)
    return findings


# -- AQP501 ------------------------------------------------------------------


def _check_size(mod, f, node: ast.Call, findings: List[Finding]) -> None:
    dotted = mod.resolve_call_name(node.func)
    if dotted is None or not dotted.startswith("jax."):
        return
    leaf = dotted.rsplit(".", 1)[-1]
    data_dependent = (leaf in _SIZE_REQUIRED
                      or (leaf == "where" and len(node.args) == 1
                          and not node.keywords))
    if not data_dependent:
        return
    if any(kw.arg == "size" for kw in node.keywords):
        return
    findings.append(Finding(
        code="AQP501", path=mod.relpath, line=node.lineno,
        col=node.col_offset, symbol=f.qualname,
        message=(f"data-dependent-shape call `{leaf}` without `size=` "
                 "in jit-traced code — errors under jit, or retraces "
                 "per distinct count at the jit boundary; pass "
                 "size=/fill_value= like _gather_blocks does")))


# -- AQP502 ------------------------------------------------------------------


def _check_slice(mod, f, node: ast.Subscript,
                 findings: List[Finding]) -> None:
    # only at a *declared* jit boundary do we know which params are
    # traced; helpers deeper in the trace often take static Python ints
    # by construction (e.g. _fold_local's num_groups)
    if not f.is_jit_root:
        return
    traced_params = set(f.params) - set(f.static_params) - {"self"}
    slices = []
    sl = node.slice
    if isinstance(sl, ast.Slice):
        slices = [sl]
    elif isinstance(sl, ast.Tuple):
        slices = [e for e in sl.elts if isinstance(e, ast.Slice)]
    for s in slices:
        for bound in (s.lower, s.upper):
            if isinstance(bound, ast.Name) and bound.id in traced_params:
                findings.append(Finding(
                    code="AQP502", path=mod.relpath, line=node.lineno,
                    col=node.col_offset, symbol=f.qualname,
                    message=(f"slice bound `{bound.id}` is a traced "
                             "parameter — shapes must be static under "
                             "jit; use lax.dynamic_slice, a mask, or "
                             "declare it static")))
                return


# -- AQP503 ------------------------------------------------------------------


def _check_static_args(project: Project, mod, f, node: ast.Call,
                       findings: List[Finding]) -> None:
    target = _single_target(project, mod, f, node)
    if target is None or not target.static_params:
        return
    for kw in node.keywords:
        if kw.arg in target.static_params and _non_hashable(kw.value):
            findings.append(Finding(
                code="AQP503", path=mod.relpath, line=node.lineno,
                col=node.col_offset, symbol=f.qualname,
                message=(f"non-hashable literal for static arg "
                         f"`{kw.arg}` of jit-rooted `{target.name}` — "
                         "jit static args must hash; pass a tuple")))


def _single_target(project: Project, mod, f, node: ast.Call):
    dotted = mod.resolve_call_name(node.func)
    if dotted is None:
        return None
    hits = project._lookup_dotted(mod, f, dotted)
    return hits[0] if len(hits) == 1 else None


def _non_hashable(value: ast.AST) -> bool:
    return isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp))
