"""AQP4xx — collective placement.

A ``psum`` outside its ``shard_map`` region fails at trace time on a
mesh but passes every single-device test; a collective naming the wrong
axis folds across the wrong mesh dimension (silently wrong totals on a
2-D mesh); and a cadence-pending fold merged outside the designated
merge step breaks the merge-then-confirm termination contract from the
collective-cadence design (PR 6) — bounds stop being sound-but-stale
and become simply wrong.

AQP401 — collective call in a function not reachable from any
  ``shard_map``-wrapped callable.
AQP402 — collective without an axis name, or with a literal axis not in
  the known AQP mesh-axis vocabulary (``shards``, ``shardN``, ``data``,
  ``model``, ``pod``). Non-literal axis expressions (a parameter, a
  ``ShardInfo`` field) are accepted — they are resolved at mesh-build
  time against the real mesh.
AQP403 — a collective whose arguments touch the cadence-pending fold
  slots (``pend_sums``/``pend_vmin``/``pend_vmax``/``pend_hist``)
  outside the designated merge functions (``_merge_refresh``,
  ``_merge_refresh_pass``, ``flush``). The per-round scalar ``pmax``
  hint on ``pend_rounds`` is deliberately NOT in the payload set.
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional

from aqplint.core import Finding, Project

_COLLECTIVES = {"psum", "pmin", "pmax", "pmean", "all_gather",
                "ppermute", "axis_index", "psum_scatter", "all_to_all"}
#: collectives that take no payload (axis is the first positional)
_AXIS_FIRST = {"axis_index"}

_KNOWN_AXES = {"shards", "data", "model", "pod"}
_SHARD_AXIS_RE = re.compile(r"^shard\d+$")

_PENDING_SLOTS = {"pend_sums", "pend_vmin", "pend_vmax", "pend_hist"}
_MERGE_FUNCS = {"_merge_refresh", "_merge_refresh_pass", "flush"}


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules.values():
        for f in mod.functions.values():
            for node in ast.walk(f.node):
                if not isinstance(node, ast.Call):
                    continue
                if mod.enclosing_function(node.lineno) != f.qualname:
                    continue
                leaf = _collective_leaf(mod, node)
                if leaf is None:
                    continue
                if f.fid not in project.sharded:
                    findings.append(_f(
                        "AQP401", mod, node, f.qualname,
                        f"collective `{leaf}` in code not reachable from "
                        "any shard_map-wrapped function — it will fail "
                        "at trace time on a mesh (and no single-device "
                        "test can see it)"))
                _check_axis(mod, node, f.qualname, leaf, findings)
                _check_pending(mod, node, f.qualname, leaf, findings)
    return findings


def _collective_leaf(mod, node: ast.Call) -> Optional[str]:
    dotted = mod.resolve_call_name(node.func)
    if dotted is None:
        return None
    leaf = dotted.rsplit(".", 1)[-1]
    if leaf not in _COLLECTIVES:
        return None
    # accept jax.lax.psum, lax.psum (unresolved local), bare psum import
    if "." in dotted and "lax" not in dotted and not dotted.startswith(
            "jax."):
        return None
    return leaf


def _check_axis(mod, node: ast.Call, symbol: str, leaf: str,
                findings: List[Finding]) -> None:
    axis = None
    for kw in node.keywords:
        if kw.arg in ("axis_name", "axis"):
            axis = kw.value
            break
    if axis is None:
        pos = 0 if leaf in _AXIS_FIRST else 1
        if len(node.args) > pos:
            axis = node.args[pos]
    if axis is None:
        findings.append(_f(
            "AQP402", mod, node, symbol,
            f"collective `{leaf}` without an axis name — it must name "
            "the AQP mesh axis explicitly"))
        return
    for lit in _literal_axes(axis):
        if lit not in _KNOWN_AXES and not _SHARD_AXIS_RE.match(lit):
            findings.append(_f(
                "AQP402", mod, node, symbol,
                f"collective `{leaf}` names unknown mesh axis "
                f"'{lit}' (known: {sorted(_KNOWN_AXES)} or shardN)"))


def _literal_axes(axis: ast.AST) -> List[str]:
    if isinstance(axis, ast.Constant) and isinstance(axis.value, str):
        return [axis.value]
    if isinstance(axis, (ast.Tuple, ast.List)):
        out = []
        for e in axis.elts:
            out.extend(_literal_axes(e))
        return out
    return []


def _check_pending(mod, node: ast.Call, symbol: str, leaf: str,
                   findings: List[Finding]) -> None:
    touches = set()
    for arg in list(node.args) + [k.value for k in node.keywords]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Name) and sub.id in _PENDING_SLOTS:
                touches.add(sub.id)
            elif isinstance(sub, ast.Attribute) and \
                    sub.attr in _PENDING_SLOTS:
                touches.add(sub.attr)
    if not touches:
        return
    func_leaf = symbol.rsplit(".", 1)[-1]
    if func_leaf not in _MERGE_FUNCS:
        findings.append(_f(
            "AQP403", mod, node, symbol,
            f"collective `{leaf}` folds cadence-pending slot(s) "
            f"{sorted(touches)} outside the designated merge step "
            f"(allowed: {sorted(_MERGE_FUNCS)}) — merging pending "
            "deltas off-cadence breaks merge-then-confirm termination"))


def _f(code: str, mod, node: ast.AST, symbol: str,
       message: str) -> Finding:
    return Finding(code=code, path=mod.relpath, line=node.lineno,
                   col=node.col_offset, symbol=symbol, message=message)
