"""AQP1xx — jit-region purity.

A host sync inside a traced region either fails at trace time
(``TracerConversionError``) or, worse, silently freezes a traced value
at its trace-time placeholder. A ``print`` or host-RNG call runs once
at trace time and never again. None of these fail a unit test that only
checks values, so we flag them statically: no host-sync or
side-effecting calls in any function reachable from a ``lax.while_loop``
body, ``pallas_call`` kernel, ``shard_map``-wrapped loop, or jit root.

AQP101 — host-sync / side-effecting call in jit-traced code.

``float(x)`` / ``int(x)`` / ``bool(x)`` are only flagged when ``x`` is
not provably static: constants and parameters declared in the jit
root's ``static_argnames`` are fine.
"""

from __future__ import annotations

import ast
from typing import List

from aqplint.core import Finding, Project

#: method calls that force a device->host transfer
_SYNC_ATTRS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}

#: dotted prefixes that are host-only (after import-alias resolution);
#: jax.numpy / jax.random resolve under "jax." and are NOT matched
_HOST_PREFIXES = ("numpy.", "time.", "random.")

#: exact host-only dotted names
_HOST_NAMES = {"print", "input", "breakpoint",
               "numpy.asarray", "numpy.array"}

_CAST_BUILTINS = {"float", "int", "bool"}


def _static_names(project: Project, mod, qualname: str) -> set:
    """Static params declared anywhere up the lexical nesting chain."""
    out = set()
    parts = qualname.split(".")
    for i in range(len(parts)):
        anc = ".".join(parts[: i + 1])
        f = mod.functions.get(anc)
        if f is not None:
            out.update(f.static_params)
    return out


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules.values():
        for f in mod.functions.values():
            if f.fid not in project.traced:
                continue
            statics = _static_names(project, mod, f.qualname)
            for node in ast.walk(f.node):
                if not isinstance(node, ast.Call):
                    continue
                if mod.enclosing_function(node.lineno) != f.qualname:
                    continue
                hit = _classify(mod, node, statics)
                if hit:
                    findings.append(Finding(
                        code="AQP101", path=mod.relpath,
                        line=node.lineno, col=node.col_offset,
                        symbol=f.qualname,
                        message=(f"host-sync/side-effecting call `{hit}` "
                                 "in jit-traced code (reachable from a "
                                 "while_loop body, pallas kernel, "
                                 "shard_map region, or jit root)")))
    return findings


def _classify(mod, node: ast.Call, statics: set):
    """Return a display name if this call is a purity violation."""
    func = node.func
    if isinstance(func, ast.Attribute) and func.attr in _SYNC_ATTRS:
        # .copy() etc. are excluded above; receiver type is unknown, but
        # these method names are device-array-specific in this codebase
        return f".{func.attr}()"
    dotted = mod.resolve_call_name(func)
    if dotted is None:
        return None
    if dotted in _HOST_NAMES:
        return dotted
    for pref in _HOST_PREFIXES:
        if dotted.startswith(pref):
            # numpy.ndarray annotations etc. are not calls; anything
            # *called* under a host-only prefix runs on the host
            return dotted
    if dotted in _CAST_BUILTINS:
        if not node.args:
            return None
        arg = node.args[0]
        if isinstance(arg, ast.Constant):
            return None
        if isinstance(arg, ast.Name) and arg.id in statics:
            return None
        return f"{dotted}(<traced value>)"
    return None
