"""Benchmark-report row matching and threshold comparison.

Shared by ``tools/check_perf_regression.py`` (the CI perf guard) and
the aqplint tooling (the retrace sanitizer's budget reports use the
same row-keyed JSON shape). Pure functions over the committed
``benchmarks/results/BENCH_*.json`` format::

    {"rows": [{"nb": 512, "hist": true, "fused_blocks_per_s": 810.2,
               ...}, ...]}

Rows are matched across reports by a key tuple of field values; a quick
sweep point is also a row of the full baseline sweep, so comparisons
are like-for-like.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Sequence, Tuple


def rows_by_key(path: Path, key_fields: Sequence[str]) -> Dict[tuple, dict]:
    """Index a report's rows by the tuple of ``key_fields`` values."""
    report = json.loads(Path(path).read_text())
    return {tuple(row[k] for k in key_fields): row
            for row in report["rows"]}


def compare(got: float, want: float, threshold: float,
            direction: str = "higher") -> Tuple[bool, float, str]:
    """Threshold comparison against a baseline value.

    ``direction="higher"`` (throughput): fail when ``got`` drops below
    ``want * (1 - threshold)``. ``direction="lower"`` (latency): fail
    when ``got`` exceeds ``want * (1 + threshold)``. Returns
    ``(ok, bound, bound_label)`` where ``bound`` is the failing edge.
    """
    if direction == "lower":
        bound = want * (1.0 + threshold)
        return got <= bound, bound, "ceiling"
    if direction != "higher":
        raise ValueError(f"unknown direction {direction!r}")
    bound = want * (1.0 - threshold)
    return got >= bound, bound, "floor"


def meets_floor(got: float, floor: float) -> bool:
    """Absolute machine-independent floor — thresholds never soften it."""
    return float(got) >= float(floor)
