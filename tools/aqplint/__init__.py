"""aqplint: a repo-specific, JAX-aware static-analysis suite.

Machine-checks the AQP engine's soundness invariants — the conventions
that keep the paper's (1-delta) interval guarantees true but that no
unit test can see failing (a silent f32 demotion still *runs*; a
``_device`` twin with a drifted parameter list still *passes* the tests
that never call it; a host sync inside ``lax.while_loop`` merely makes
the loop slow or untraceable later).

Five AST passes over a shared module-walker / call-graph core
(:mod:`aqplint.core`):

  * ``purity``       (AQP1xx) — no host-sync / side-effecting calls in
    code reachable from ``lax.while_loop`` bodies, ``pallas_call``
    kernels or ``shard_map``-wrapped loops;
  * ``parity``       (AQP2xx) — every bounder / stopping-condition API
    with a ``_batch`` / ``_device`` twin keeps coverage and signatures
    in sync;
  * ``dtype``        (AQP3xx) — no f32 literals/casts in bound-eval
    code; device-twin call sites sit behind ``state.require_x64``;
  * ``collectives``  (AQP4xx) — ``psum/pmin/pmax/axis_index`` name the
    AQP mesh axis, stay inside ``shard_map`` regions, and
    cadence-pending folds merge only at the designated merge step;
  * ``shapes``       (AQP5xx) — static-shape / retrace hygiene in
    jitted code (data-dependent shapes, traced-value slicing,
    non-hashable static args).

Plus one *dynamic* sanitizer (:mod:`aqplint.retrace`): a pytest helper
that counts XLA compilations against committed budgets
(``retrace_budgets.json``), so shape-padding fixes cannot silently
regress into per-round retraces.

CLI: ``python -m aqplint src tests`` (see ``docs/static_analysis.md``).
Inline suppression: ``# aqplint: disable=CODE(reason)``. Committed
baseline: ``tools/aqplint/baseline.json`` — new findings beyond the
baseline fail CI.
"""

from aqplint.core import Finding, Project  # noqa: F401

__version__ = "1.0"
