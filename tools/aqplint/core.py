"""Shared analysis core: module walker, suppressions, call graph,
jit/shard reachability.

Every pass operates on a :class:`Project` — the parsed ASTs of every
``.py`` file under the analyzed roots, with

  * a per-module symbol table (functions incl. nested/methods, classes,
    import aliases),
  * a project-wide call graph (name-resolved where possible, with a
    conservative by-method-name fallback for attribute calls so
    dynamically-dispatched twins like ``bounder.interval_batch_device``
    still get edges),
  * the *traced* closure: functions reachable from jit entry points
    (``jax.jit`` / ``functools.partial(jax.jit, ...)`` decorations,
    ``lax.while_loop`` / ``lax.cond`` / ``lax.scan`` bodies,
    ``pallas_call`` kernels, ``shard_map``-wrapped callables, and
    closures passed via ``*_fn`` / ``*_fns`` / ``*_src`` callback
    parameters — the repo's traced-callback convention),
  * the *sharded* closure: functions reachable from ``shard_map``
    callables only (collectives must stay inside it).

The analysis is intentionally static and conservative: it never imports
the analyzed code.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

# --------------------------------------------------------------------------
# Findings & suppressions
# --------------------------------------------------------------------------

#: ``# aqplint: disable=AQP101(reason), AQP302(other reason)``
_SUPPRESS_RE = re.compile(r"#\s*aqplint:\s*disable=(.+?)\s*$")
_ENTRY_RE = re.compile(r"(AQP\d{3}|AQP0\d{2})\s*(?:\(([^()]*)\))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: a code, a location and a message."""

    code: str
    path: str       # repo-relative posix path
    line: int
    col: int
    symbol: str     # dotted function/class context ("" at module level)
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity — line numbers excluded so unrelated edits
        above a baselined finding do not un-baseline it."""
        return (self.code, self.path, self.symbol)

    def render(self) -> str:
        where = f"{self.path}:{self.line}:{self.col}"
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{where}: {self.code}{sym} {self.message}"


@dataclasses.dataclass
class Suppression:
    line: int       # line the suppression applies to
    code: str
    reason: str
    comment_line: int
    used: bool = False


def parse_suppressions(source: str) -> List[Suppression]:
    """Parse inline ``# aqplint: disable=CODE(reason)`` comments.

    Only real COMMENT tokens count (the marker inside a string literal —
    e.g. a fixture snippet in a test — is ignored). A suppression on a
    code line applies to that line; one on a comment-only line applies
    to the next line. Reasons are mandatory — a missing/empty reason is
    reported by the driver as AQP001 rather than honoured.
    """
    out: List[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return out
    lines = source.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        i = tok.start[0]
        before = lines[i - 1][: tok.start[1]].strip() if i <= len(lines) else ""
        target = i if before else i + 1
        for code, reason in _ENTRY_RE.findall(m.group(1)):
            out.append(Suppression(line=target, code=code,
                                   reason=(reason or "").strip(),
                                   comment_line=i))
    return out


# --------------------------------------------------------------------------
# Module model
# --------------------------------------------------------------------------


@dataclasses.dataclass
class FunctionInfo:
    """One ``def`` — module-level, method, or nested closure."""

    module: "Module"
    qualname: str                  # e.g. "Bounder.lbound_batch", "f.inner"
    node: ast.AST                  # FunctionDef / AsyncFunctionDef / Lambda
    params: Tuple[str, ...]
    lineno: int
    parent_class: Optional[str]    # immediate enclosing class name
    static_params: Tuple[str, ...] = ()   # from jit static_argnames
    annotations: Dict[str, str] = dataclasses.field(default_factory=dict)
    is_jit_root: bool = False
    is_shard_root: bool = False
    #: local names assigned a function value (``loop_body = a if c else b``)
    aliases: Dict[str, List["FunctionInfo"]] = dataclasses.field(
        default_factory=dict)

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def fid(self) -> str:
        return f"{self.module.name}:{self.qualname}"


@dataclasses.dataclass
class ClassInfo:
    module: "Module"
    name: str
    node: ast.ClassDef
    bases: Tuple[str, ...]         # textual base names ("Bounder", ...)
    methods: Dict[str, FunctionInfo]


class Module:
    """One parsed source file with its symbol table."""

    def __init__(self, path: Path, root: Path, repo_root: Path):
        self.path = path
        self.relpath = path.relative_to(repo_root).as_posix()
        self.name = _module_name(path, root)
        self.source = path.read_text()
        self.source_lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        self.suppressions = parse_suppressions(self.source)
        self.functions: Dict[str, FunctionInfo] = {}
        self.classes: Dict[str, ClassInfo] = {}
        self.imports: Dict[str, str] = {}     # local alias -> dotted target
        self._index()

    # -- symbol table --------------------------------------------------------

    def _index(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = (
                        f"{node.module}.{a.name}")
        self._index_scope(self.tree.body, prefix="", parent_class=None)

    def _index_scope(self, body, prefix: str,
                     parent_class: Optional[str]) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{node.name}"
                args = (node.args.posonlyargs + node.args.args
                        + node.args.kwonlyargs)
                info = FunctionInfo(
                    module=self, qualname=qual, node=node,
                    params=tuple(a.arg for a in args),
                    lineno=node.lineno, parent_class=parent_class,
                    static_params=_jit_static_params(node, self.imports),
                    annotations={a.arg: _ann_leaf(a.annotation)
                                 for a in args if a.annotation is not None},
                    is_jit_root=_is_jit_decorated(node, self.imports))
                self.functions[qual] = info
                self._index_scope(node.body, prefix=f"{qual}.",
                                  parent_class=None)
            elif isinstance(node, ast.ClassDef):
                qual = f"{prefix}{node.name}"
                self._index_scope(node.body, prefix=f"{qual}.",
                                  parent_class=node.name)
                methods = {
                    f.name: f for f in self.functions.values()
                    if f.qualname.startswith(f"{qual}.")
                    and "." not in f.qualname[len(qual) + 1:]}
                self.classes[node.name] = ClassInfo(
                    module=self, name=node.name, node=node,
                    bases=tuple(_base_name(b) for b in node.bases),
                    methods=methods)
            elif isinstance(node, (ast.If, ast.Try, ast.With)):
                inner = list(getattr(node, "body", []))
                for attr in ("orelse", "finalbody"):
                    inner.extend(getattr(node, attr, []))
                for h in getattr(node, "handlers", []):
                    inner.extend(h.body)
                self._index_scope(inner, prefix=prefix,
                                  parent_class=parent_class)

    # -- name resolution -----------------------------------------------------

    def resolve_call_name(self, func: ast.AST) -> Optional[str]:
        """Best-effort dotted name of a call target: ``jnp.nonzero`` with
        ``import jax.numpy as jnp`` -> ``jax.numpy.nonzero``."""
        parts: List[str] = []
        node = func
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head = self.imports.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])

    def enclosing_function(self, lineno: int) -> str:
        """Innermost function qualname containing ``lineno`` ("" if
        module level)."""
        best, best_span = "", None
        for f in self.functions.values():
            end = getattr(f.node, "end_lineno", f.lineno)
            if f.lineno <= lineno <= end:
                span = end - f.lineno
                if best_span is None or span <= best_span:
                    best, best_span = f.qualname, span
        return best


def _module_name(path: Path, root: Path) -> str:
    rel = path.relative_to(root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else root.name


def _base_name(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def _ann_leaf(node: ast.AST) -> str:
    """Textual leaf of an annotation: ``DevStatsBatch``,
    ``state.StatsBatch`` -> ``StatsBatch``, ``"StatsBatch"`` (string
    forward ref) -> ``StatsBatch``."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.rsplit(".", 1)[-1].strip('"')
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):      # Optional[X] -> X (best effort)
        return _ann_leaf(node.slice)
    return ""


# -- jit decoration ---------------------------------------------------------

_JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}


def _call_name_with(imports: Dict[str, str], func: ast.AST) -> Optional[str]:
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    parts.reverse()
    head = imports.get(parts[0], parts[0])
    return ".".join([head] + parts[1:])


def _is_jit_name(name: Optional[str]) -> bool:
    return name in _JIT_NAMES


def _is_jit_decorated(node, imports) -> bool:
    for dec in getattr(node, "decorator_list", []):
        if _is_jit_name(_call_name_with(imports, dec)):
            return True
        if isinstance(dec, ast.Call):
            name = _call_name_with(imports, dec.func)
            if _is_jit_name(name):
                return True
            if name in ("functools.partial", "partial") and dec.args:
                if _is_jit_name(_call_name_with(imports, dec.args[0])):
                    return True
    return False


def _jit_static_params(node, imports) -> Tuple[str, ...]:
    """static_argnames / static_argnums declared on a jit decoration."""
    for dec in getattr(node, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        name = _call_name_with(imports, dec.func)
        inner_jit = (name in ("functools.partial", "partial") and dec.args
                     and _is_jit_name(_call_name_with(imports, dec.args[0])))
        if not (_is_jit_name(name) or inner_jit):
            continue
        statics: List[str] = []
        params = [a.arg for a in (node.args.posonlyargs + node.args.args
                                  + node.args.kwonlyargs)]
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                statics.extend(_str_elements(kw.value))
            elif kw.arg == "static_argnums":
                for idx in _int_elements(kw.value):
                    if 0 <= idx < len(params):
                        statics.append(params[idx])
        return tuple(statics)
    return ()


def _str_elements(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return out
    return []


def _int_elements(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)]
    return []


# --------------------------------------------------------------------------
# Project: modules + call graph + traced/sharded closures
# --------------------------------------------------------------------------

#: callables whose function-valued arguments are traced entry points
_TRACING_CALLEES = {
    "jax.lax.while_loop", "lax.while_loop",
    "jax.lax.cond", "lax.cond",
    "jax.lax.scan", "lax.scan",
    "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.map", "lax.map",
    "jax.jit", "jit", "jax.pjit",
    "jax.vmap", "jax.pmap",
    "jax.checkpoint", "jax.remat",
    "jax.experimental.pallas.pallas_call", "pallas.pallas_call",
    "pl.pallas_call", "pallas_call",
}
_SHARD_CALLEES = {
    "jax.experimental.shard_map.shard_map", "shard_map",
    "jax.experimental.shard_map", "smap",
}
#: closures passed under these parameter-name patterns are traced by
#: convention (the engine hands CI-refresh closures to the loop builders)
_CALLBACK_PARAM_RE = re.compile(r"(_fn|_fns|_src)$")

#: attribute-call fallback resolution skips nothing by default; names
#: here would be too ubiquitous to resolve by method name alone
_FALLBACK_SKIP = {"get", "put", "copy", "items", "keys", "values",
                  "append", "extend", "pop", "add", "join", "split",
                  "update", "replace", "_replace", "format", "read",
                  "write", "sum", "any", "all", "min", "max", "mean",
                  "reshape", "astype", "flatten"}


class Project:
    """All modules under the analyzed roots + the project call graph."""

    def __init__(self, roots: Iterable[Path], repo_root: Path):
        self.repo_root = repo_root
        self.modules: Dict[str, Module] = {}
        for root in roots:
            root = root.resolve()
            files = [root] if root.is_file() else sorted(
                p for p in root.rglob("*.py")
                if "__pycache__" not in p.parts)
            base = root.parent if root.is_file() else root
            for f in files:
                try:
                    mod = Module(f, base, repo_root)
                except SyntaxError:
                    continue
                self.modules[mod.name] = mod
        # symbol indexes
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        for mod in self.modules.values():
            for f in mod.functions.values():
                self.functions[f.fid] = f
                self.by_name.setdefault(f.name, []).append(f)
        self._build_graph()
        self.traced: Set[str] = self._closure(
            {f.fid for f in self.functions.values() if f.is_jit_root})
        self.sharded: Set[str] = self._closure(
            {f.fid for f in self.functions.values() if f.is_shard_root})

    # -- call graph ----------------------------------------------------------

    def _build_graph(self) -> None:
        self.calls: Dict[str, Set[str]] = {fid: set()
                                           for fid in self.functions}
        # pass 0: local function aliases (loop_body = cadence_body if
        # cadence else body) so closures picked by a conditional still
        # resolve when later passed to while_loop/shard_map
        for mod in self.modules.values():
            for f in mod.functions.values():
                self._collect_aliases(mod, f)
        for mod in self.modules.values():
            for f in mod.functions.values():
                self._scan_function(mod, f)

    def _collect_aliases(self, mod: Module, f: FunctionInfo) -> None:
        for node in ast.walk(f.node):
            if not isinstance(node, ast.Assign):
                continue
            if mod.enclosing_function(node.lineno) != f.qualname:
                continue
            if len(node.targets) != 1 or not isinstance(
                    node.targets[0], ast.Name):
                continue
            values = self._function_values(mod, f, node.value)
            if values:
                f.aliases[node.targets[0].id] = values

    def _alias_lookup(self, mod: Module, f: FunctionInfo,
                      name: str) -> List[FunctionInfo]:
        """Alias defined in ``f`` or any lexically enclosing function."""
        parts = f.qualname.split(".")
        for i in range(len(parts), 0, -1):
            anc = mod.functions.get(".".join(parts[:i]))
            if anc is not None and name in anc.aliases:
                return anc.aliases[name]
        return []

    def _scan_function(self, mod: Module, f: FunctionInfo) -> None:
        for node in ast.walk(f.node):
            if not isinstance(node, ast.Call):
                continue
            if mod.enclosing_function(node.lineno) != f.qualname:
                continue  # belongs to a nested def, scanned separately
            callee = mod.resolve_call_name(node.func)
            targets = self._resolve_targets(mod, f, node, callee)
            self.calls[f.fid].update(t.fid for t in targets)
            self._mark_roots(mod, f, node, callee)

    def _resolve_targets(self, mod: Module, f: FunctionInfo,
                         node: ast.Call,
                         callee: Optional[str]) -> List[FunctionInfo]:
        func = node.func
        # 1. plain / dotted name resolving inside the project
        if callee:
            hits = self._lookup_dotted(mod, f, callee)
            if hits:
                return hits
        if not isinstance(func, ast.Attribute):
            return []
        name = func.attr
        recv = func.value
        # 2a. typed receiver: s.reflect() with `s: DevStatsBatch` in the
        #     signature resolves to exactly that class's method — this
        #     keeps host/device twins with the same method name apart
        if isinstance(recv, ast.Name):
            ann = f.annotations.get(recv.id, "")
            cls = self._find_class(ann)
            if cls is not None:
                m = self._method_on(cls, name)
                return [m] if m is not None else []
            # self.method() resolves within the class and its subclasses
            if recv.id == "self" and f.parent_class:
                own = self._find_class(f.parent_class)
                if own is not None:
                    hits = []
                    for c in [own] + self.subclasses_of({own.name}):
                        m = c.methods.get(name)
                        if m is not None:
                            hits.append(m)
                    if hits:
                        return hits
        # 2b. external-module call (jnp.round, np.clip): the chain root
        #     is an import alias and project resolution already failed —
        #     never fall back by bare method name
        root = recv
        while isinstance(root, ast.Attribute):
            root = root.value
        if isinstance(root, ast.Name) and root.id in mod.imports:
            return []
        # 2c. attribute fallback: x.method(...) -> every project def
        #     named `method` (conservative over-approximation for
        #     dynamic dispatch: bounder.interval_batch_device)
        if name not in _FALLBACK_SKIP and name in self.by_name:
            return self.by_name[name]
        return []

    def _find_class(self, name: str) -> Optional[ClassInfo]:
        if not name:
            return None
        for mod in self.modules.values():
            if name in mod.classes:
                return mod.classes[name]
        return None

    def _method_on(self, cls: ClassInfo,
                   name: str) -> Optional[FunctionInfo]:
        """Method looked up on ``cls`` or (textually) up its base chain."""
        seen = set()
        frontier = [cls]
        while frontier:
            c = frontier.pop()
            if c.name in seen:
                continue
            seen.add(c.name)
            if name in c.methods:
                return c.methods[name]
            for b in c.bases:
                parent = self._find_class(b)
                if parent is not None:
                    frontier.append(parent)
        return None

    def _lookup_dotted(self, mod: Module, f: FunctionInfo,
                       dotted: str) -> List[FunctionInfo]:
        parts = dotted.split(".")
        leaf = parts[-1]
        # nested sibling or own-module function (innermost scope first)
        if len(parts) == 1:
            prefix = f.qualname
            while True:
                cand = f"{prefix}.{leaf}" if prefix else leaf
                if cand in mod.functions:
                    return [mod.functions[cand]]
                if "." not in prefix:
                    break
                prefix = prefix.rsplit(".", 1)[0]
            if leaf in mod.functions:
                return [mod.functions[leaf]]
            # imported plain name: "from x import f"
            tgt = mod.imports.get(leaf)
            if tgt:
                return self._lookup_qualified(tgt)
            return []
        return self._lookup_qualified(dotted)

    def _lookup_qualified(self, dotted: str) -> List[FunctionInfo]:
        """repro.kernels.ops.grouped_sums -> FunctionInfo, including
        Class.method targets and package-qualified module names."""
        parts = dotted.split(".")
        for split in range(len(parts) - 1, 0, -1):
            mod_name = ".".join(parts[:split])
            rest = ".".join(parts[split:])
            for cand_mod, mod in self.modules.items():
                if cand_mod == mod_name or cand_mod.endswith(
                        "." + mod_name) or mod_name.endswith(
                        "." + cand_mod):
                    if rest in mod.functions:
                        return [mod.functions[rest]]
                    # Class attribute: Class.method
                    if rest in mod.classes:
                        return []
        return []

    # -- traced / sharded roots ---------------------------------------------

    def _mark_roots(self, mod: Module, f: FunctionInfo, node: ast.Call,
                    callee: Optional[str]) -> None:
        leaf = callee.rsplit(".", 1)[-1] if callee else ""
        is_tracer = (callee in _TRACING_CALLEES
                     or leaf in ("pallas_call",))
        is_shard = callee in _SHARD_CALLEES or leaf == "shard_map"
        if is_tracer or is_shard:
            for arg in list(node.args) + [k.value for k in node.keywords]:
                for target in self._function_values(mod, f, arg):
                    if is_shard:
                        target.is_shard_root = True
                    target.is_jit_root = True
                    # the callback executes within the caller's trace, so
                    # it is also a call edge (shard reachability needs it)
                    self.calls[f.fid].add(target.fid)
            return
        # traced-callback convention: f(..., refresh_fn=g) / build(g)
        # where the receiving parameter matches _fn/_fns/_src
        resolved = self._resolve_targets(mod, f, node, callee)
        param_map: Dict[int, str] = {}
        target_info = resolved[0] if len(resolved) == 1 else None
        if target_info is not None:
            params = [p for p in target_info.params if p != "self"]
            param_map = dict(enumerate(params))
        for i, arg in enumerate(node.args):
            pname = param_map.get(i, "")
            if _CALLBACK_PARAM_RE.search(pname):
                for t in self._function_values(mod, f, arg):
                    t.is_jit_root = True
                    self.calls[f.fid].add(t.fid)
        for kw in node.keywords:
            if kw.arg and _CALLBACK_PARAM_RE.search(kw.arg):
                for t in self._function_values(mod, f, kw.value):
                    t.is_jit_root = True
                    self.calls[f.fid].add(t.fid)

    def _function_values(self, mod: Module, f: FunctionInfo,
                         expr: ast.AST) -> List[FunctionInfo]:
        """Function objects an argument expression may denote: a plain
        name, a ``functools.partial(name, ...)`` wrap, or a nested-def
        reference. Tuples/lists are walked elementwise."""
        out: List[FunctionInfo] = []
        if isinstance(expr, (ast.Tuple, ast.List)):
            for e in expr.elts:
                out.extend(self._function_values(mod, f, e))
            return out
        if isinstance(expr, ast.IfExp):
            return (self._function_values(mod, f, expr.body)
                    + self._function_values(mod, f, expr.orelse))
        if isinstance(expr, ast.Call):
            name = mod.resolve_call_name(expr.func)
            if name in ("functools.partial", "partial") and expr.args:
                return self._function_values(mod, f, expr.args[0])
            return out
        if isinstance(expr, ast.Name):
            aliased = self._alias_lookup(mod, f, expr.id)
            if aliased:
                return aliased
            return self._lookup_dotted(mod, f, expr.id)
        if isinstance(expr, ast.Attribute):
            dotted = mod.resolve_call_name(expr)
            if dotted:
                return self._lookup_dotted(mod, f, dotted)
        return out

    def _closure(self, roots: Set[str]) -> Set[str]:
        seen = set(roots)
        frontier = list(roots)
        while frontier:
            fid = frontier.pop()
            for nxt in self.calls.get(fid, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        # by-name convention: nested closures named like traced callbacks
        # (refresh_fn, flags_src) are traced even when only constructed
        for f in self.functions.values():
            if (_CALLBACK_PARAM_RE.search(f.name)
                    and f.fid not in seen):
                seen.add(f.fid)
                frontier.append(f.fid)
        while frontier:
            fid = frontier.pop()
            for nxt in self.calls.get(fid, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        return seen

    # -- class hierarchy helpers --------------------------------------------

    def subclasses_of(self, base_names: Set[str]) -> List[ClassInfo]:
        """Classes whose (textual, transitively expanded) base chain hits
        one of ``base_names``."""
        out = []
        # iterate to a fixed point over textual base names
        matches: Set[str] = set(base_names)
        changed = True
        all_classes = [c for m in self.modules.values()
                       for c in m.classes.values()]
        while changed:
            changed = False
            for c in all_classes:
                if c.name in matches:
                    continue
                if any(b in matches for b in c.bases):
                    matches.add(c.name)
                    changed = True
        for c in all_classes:
            if c.name in matches and c.name not in base_names:
                out.append(c)
        return out

    def is_traced(self, mod: Module, qualname: str) -> bool:
        return f"{mod.name}:{qualname}" in self.traced

    def is_sharded(self, mod: Module, qualname: str) -> bool:
        return f"{mod.name}:{qualname}" in self.sharded
