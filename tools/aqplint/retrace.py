"""Dynamic retrace sanitizer — the runtime counterpart of the AQP5xx
static pass.

XLA compiles one executable per (function, shape-signature). PR 3's
static-shape padding exists precisely so the round loop re-dispatches
with identical signatures and never retraces in steady state; nothing
in the value-comparing test suite would notice if that regressed — the
results stay bitwise identical, only 100x slower. This module counts
actual compilations (via ``jax_log_compiles``, whose "Compiling <name>"
records land on the jax logger) against budgets committed in
``tools/aqplint/retrace_budgets.json``.

Usage in a test::

    from aqplint.retrace import count_compiles, assert_within_budget

    run_query(...)                       # warm-up: traces + compiles
    with count_compiles() as counter:
        run_query(...)                   # steady state
    assert_within_budget("fused_scan::rerun_same_shapes", counter)

Budgets are exact ceilings: lowering a count is welcome (shrink the
budget), raising one fails until the budget file is consciously bumped
in review.
"""

from __future__ import annotations

import contextlib
import json
import logging
from pathlib import Path
from typing import Iterator, List

BUDGETS_PATH = Path(__file__).with_name("retrace_budgets.json")

#: loggers that emit "Compiling <fn> with global shapes..." records
_JAX_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class CompileCounter(logging.Handler):
    """Collects one entry per XLA compilation observed while attached."""

    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.names: List[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            # "Compiling <name> with global shapes and types [...]"
            self.names.append(msg.split(" ")[1])

    @property
    def count(self) -> int:
        return len(self.names)


@contextlib.contextmanager
def count_compiles() -> Iterator[CompileCounter]:
    """Count XLA compilations inside the ``with`` block; restores
    ``jax_log_compiles`` and logger state on exit."""
    import jax

    counter = CompileCounter()
    prev = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    loggers = [logging.getLogger(name) for name in _JAX_LOGGERS]
    prev_levels = [lg.level for lg in loggers]
    for lg in loggers:
        lg.addHandler(counter)
        if lg.level > logging.WARNING:
            lg.setLevel(logging.WARNING)
    try:
        yield counter
    finally:
        for lg, lvl in zip(loggers, prev_levels):
            lg.removeHandler(counter)
            lg.setLevel(lvl)
        jax.config.update("jax_log_compiles", prev)


def load_budgets() -> dict:
    return json.loads(BUDGETS_PATH.read_text())


def assert_within_budget(key: str, counter: CompileCounter) -> None:
    """Fail if ``counter`` saw more compilations than the committed
    budget for ``key`` (see ``retrace_budgets.json``)."""
    budgets = load_budgets()
    if key not in budgets:
        raise KeyError(
            f"no retrace budget for {key!r} in {BUDGETS_PATH}; add it "
            "with the measured steady-state count")
    budget = int(budgets[key])
    if counter.count > budget:
        compiled = ", ".join(counter.names[:20])
        raise AssertionError(
            f"retrace budget exceeded for {key!r}: {counter.count} "
            f"compilation(s) observed, budget {budget}. Compiled: "
            f"[{compiled}]. If this increase is intentional, bump "
            f"{BUDGETS_PATH.name}; otherwise a shape signature is "
            "varying per call (see docs/static_analysis.md).")
