"""CLI driver: ``python -m aqplint [paths...]``.

Exit codes: 0 clean (no findings beyond the baseline), 1 new findings,
2 usage or internal error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from aqplint import baseline as baseline_mod
from aqplint.core import Finding, Project
from aqplint.passes import ALL_PASSES


def build_findings(project: Project,
                   passes=ALL_PASSES) -> List[Finding]:
    """Run every pass, apply inline suppressions, and append the
    suppression-hygiene findings (AQP001/AQP002)."""
    raw: List[Finding] = []
    for _name, run in passes:
        raw.extend(run(project))

    modules_by_path = {m.relpath: m for m in project.modules.values()}
    kept: List[Finding] = []
    for f in raw:
        mod = modules_by_path.get(f.path)
        suppressed = False
        if mod is not None:
            for s in mod.suppressions:
                if s.code == f.code and s.line == f.line:
                    s.used = True
                    if s.reason:
                        suppressed = True
                    # empty reason: the suppression is NOT honoured —
                    # AQP001 below points at it
        if not suppressed:
            kept.append(f)

    for mod in modules_by_path.values():
        for s in mod.suppressions:
            if not s.reason:
                kept.append(Finding(
                    code="AQP001", path=mod.relpath, line=s.comment_line,
                    col=0, symbol=mod.enclosing_function(s.comment_line),
                    message=(f"suppression of {s.code} without a reason "
                             "— use `# aqplint: disable="
                             f"{s.code}(why it is safe)`")))
            elif not s.used:
                kept.append(Finding(
                    code="AQP002", path=mod.relpath, line=s.comment_line,
                    col=0, symbol=mod.enclosing_function(s.comment_line),
                    message=(f"unused suppression of {s.code} — the "
                             "finding it silenced is gone; delete the "
                             "comment")))
    kept.sort(key=lambda f: (f.path, f.line, f.code))
    return kept


def main(argv: List[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m aqplint",
        description=("JAX-aware static analysis for the AQP engine's "
                     "soundness invariants (see docs/static_analysis.md)"))
    parser.add_argument("paths", nargs="*", default=["src", "tests"],
                        help="files/directories to analyze "
                             "(default: src tests)")
    parser.add_argument("--baseline", default="tools/aqplint/baseline.json",
                        help="baseline file (default: %(default)s)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline: report everything")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write current findings as the new baseline "
                             "and exit 0")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as JSON")
    args = parser.parse_args(argv)

    roots = [Path(p) for p in args.paths]
    missing = [p for p in roots if not p.exists()]
    if missing:
        print(f"aqplint: no such path: {missing[0]}", file=sys.stderr)
        return 2

    try:
        project = Project(roots, repo_root=Path.cwd())
        findings = build_findings(project)
    except Exception as exc:  # internal error must not look like "clean"
        print(f"aqplint: internal error: {exc!r}", file=sys.stderr)
        return 2

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        baseline_mod.save(baseline_path, findings)
        print(f"aqplint: wrote {len(findings)} finding(s) to "
              f"{baseline_path}")
        return 0

    base = {} if args.no_baseline else baseline_mod.load(baseline_path)
    new, stale = baseline_mod.diff(findings, base)

    if args.as_json:
        print(json.dumps({
            "new": [vars(f) for f in new],
            "baselined": len(findings) - len(new),
            "stale_baseline_keys": stale,
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        for k in stale:
            print(f"aqplint: stale baseline entry {k} — finding is "
                  "gone, shrink the baseline with --write-baseline")
        n_mod = len(project.modules)
        n_base = len(findings) - len(new)
        tail = f" ({n_base} baselined)" if n_base else ""
        print(f"aqplint: {len(new)} finding(s) in {n_mod} module(s), "
              f"{len(ALL_PASSES)} passes{tail}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
